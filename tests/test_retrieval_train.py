"""Retrieval-tower training (train/retrieval_trainer.py, DESIGN.md §12):
serving-consistent loss, grad-accumulation metric parity, the
hand-computed multi-target eval pin, trained ≫ untrained end-to-end, and
the generic SlotProgram serve loop both engines share."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.retrieval import get_retrieval_config
from repro.train import metrics as M
from repro.train import retrieval_trainer as rt
from repro.train.trainer import make_optimizer, make_train_step


# ---------------------------------------------------------------------------
# Multi-target eval pin (ISSUE 8 satellite): MAP/RR/accuracy on a
# hand-computed 4-request example — ties, excludes, -1 padding
# ---------------------------------------------------------------------------

def test_multi_target_eval_pinned_hand_example():
    """d=6 catalog, 4 requests:

    r0: clean ranking, two targets {0, 2} at ranks 1 and 3
        -> AP = (1/1 + 2/3)/2 = 5/6;  RR(t=0) = 1;  acc hit.
    r1: excludes {0, 1} knock out the two best items; target 3 (-1 pad)
        lands at rank 2 behind item 2 -> AP = 1/2; RR = 1/2; acc miss.
    r2: 4-way tie at the top, target 2 -> stable sort ranks it 3rd
        (AP = 1/3), mid-rank RR = 1/(0 + 3/2 + 1) = 2/5, tied argmax
        resolves to item 0 -> acc miss.
    r3: 2-way tie {1, 2}, targets {1, 3}: stable order 1,2,0,3 ->
        AP = (1/1 + 2/4)/2 = 3/4; RR(t=1) mid-rank = 1/1.5 = 2/3;
        argmax -> item 1 -> acc hit.
    """
    scores = np.array([
        [5.0, 4.0, 3.0, 2.0, 1.0, 0.0],
        [9.0, 8.0, 7.0, 1.0, 0.0, 0.0],
        [1.0, 1.0, 1.0, 1.0, 0.0, 0.0],
        [0.0, 2.0, 2.0, 0.0, 0.0, 0.0],
    ])
    targets = np.array([[0, 2], [3, -1], [2, -1], [1, 3]])
    excludes = np.array([[-1, -1], [0, 1], [-1, -1], [-1, -1]])

    assert M.mean_average_precision(scores, targets, excludes=excludes) \
        == pytest.approx((5 / 6 + 1 / 2 + 1 / 3 + 3 / 4) / 4)
    assert M.reciprocal_rank(scores, targets[:, 0], exclude=excludes) \
        == pytest.approx((1.0 + 1 / 2 + 2 / 5 + 2 / 3) / 4)
    assert M.accuracy(scores, targets[:, 0], exclude=excludes) \
        == pytest.approx(50.0)


# ---------------------------------------------------------------------------
# Grad-accumulation metric parity (the trainer bug ISSUE 8 fixed)
# ---------------------------------------------------------------------------

def test_microbatch_metric_parity():
    """microbatch=4 must report the SAME step metrics as microbatch=1 on
    the same effective batch.  The old path kept only the LAST chunk's
    metrics (loss was averaged, aux metrics were not), so any
    per-example-mean metric — here the retrieval loss's target_mass —
    silently diverged from the full-batch twin."""
    rcfg = get_retrieval_config("eval2k", m=200)
    loss_fn = rt.make_retrieval_loss(rcfg)
    prompts, targets = rt.make_retrieval_dataset(rcfg, 16, seed=3)
    batch = {"p": jnp.asarray(prompts), "q": jnp.asarray(targets)}

    tc = TrainConfig(optimizer="sgd", learning_rate=0.1, momentum=0.0,
                     grad_clip_norm=0.0, warmup_steps=0)
    tx = make_optimizer(tc)
    from repro.serving.retrieval import init_retrieval_params
    p0 = init_retrieval_params(rcfg)

    full = make_train_step(loss_fn, tx, microbatch=1, donate=False)
    acc = make_train_step(loss_fn, tx, microbatch=4, donate=False)
    p1, _, m1 = full(p0, tx.init(p0), batch)
    p2, _, m2 = acc(p0, tx.init(p0), batch)

    assert set(m1) == set(m2) == {"loss", "grad_norm", "target_mass"}
    for key in sorted(m1):
        np.testing.assert_allclose(np.asarray(m1[key]),
                                   np.asarray(m2[key]),
                                   rtol=1e-5, atol=1e-6, err_msg=key)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), p1, p2)


# ---------------------------------------------------------------------------
# Serving-consistent loss + dataset
# ---------------------------------------------------------------------------

def test_loss_uses_the_serving_spec_on_both_sides():
    """BloomIO.build would hash the OUTPUT side with seed+1; serving
    encodes and decodes with ONE spec (rcfg.spec()), so the training
    embedding must too — otherwise the trained tower's rankings decode
    through the wrong hashes."""
    rcfg = get_retrieval_config("eval2k")
    emb = rt.make_retrieval_emb(rcfg)
    assert emb.spec_in == emb.spec_out == rcfg.spec()


def test_dataset_is_the_seeded_zipf_stream():
    rcfg = get_retrieval_config("eval2k")
    p1, q1 = rt.make_retrieval_dataset(rcfg, 32, seed=7)
    p2, q2 = rt.make_retrieval_dataset(rcfg, 32, seed=7)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(q1, q2)
    assert p1.shape == (32, rcfg.c_max) and q1.shape == (32, 2)
    # prompts and held-out targets are disjoint within a request
    for i in range(32):
        ps = set(int(v) for v in p1[i] if v >= 0)
        qs = set(int(v) for v in q1[i] if v >= 0)
        assert ps and qs and not (ps & qs)
    assert p1.max() < rcfg.d and p1.min() >= 0


# ---------------------------------------------------------------------------
# End-to-end: train -> serve through the slot pool -> tie-aware eval
# ---------------------------------------------------------------------------

def test_trained_tower_beats_untrained_through_serving():
    """The ISSUE-8 acceptance margin at test scale: a short training run
    at 1/5 compression, served through RetrievalEngine (the generic slot
    loop), must beat the untrained tower's MAP by >= 3x."""
    rcfg = get_retrieval_config("eval2k")          # m=400 = d/5
    tc = rt.default_train_config(steps=200)
    row = rt.train_and_eval_point(rcfg, tc, n_pairs=256, batch_size=64,
                                  n_eval=48, n_slots=8)
    assert row["n_evaluated"] == 48
    assert row["map"] >= 3.0 * row["untrained_map"], row
    assert row["rr"] > row["untrained_rr"], row


# ---------------------------------------------------------------------------
# The generic serve loop (tentpole): one program-driven loop, two engines
# ---------------------------------------------------------------------------

def test_run_slot_loop_is_the_engine_loop():
    """Driving the RetrievalProgram through engine.run_slot_loop
    DIRECTLY reproduces RetrievalEngine.run bit-for-bit — the engine is
    a thin wrapper over the shared program-driven loop, not a parallel
    implementation."""
    from repro.serving.engine import PrefillPool, run_slot_loop
    from repro.serving.loadgen import (RetrievalLoadSpec,
                                       assert_fresh_instances,
                                       retrieval_workload)
    from repro.serving.retrieval import (RetrievalEngine,
                                         RetrievalProgram,
                                         init_retrieval_params)

    rcfg = get_retrieval_config("eval2k")
    params = init_retrieval_params(rcfg)
    load = RetrievalLoadSpec(n_requests=12, catalog=rcfg.d,
                             c_max=rcfg.c_max, rate=2.0, seed=4)
    wl = retrieval_workload(load)

    engine = RetrievalEngine(rcfg, params, n_slots=4)
    wl_a = [r.fresh_copy() for r in wl]
    res_a, st_a = engine.run(wl_a)

    program = RetrievalProgram(rcfg, n_slots=4)
    pool = PrefillPool(None, params, topk=rcfg.topk, program=program)
    wl_b = [r.fresh_copy() for r in wl]
    assert_fresh_instances(wl_b)
    res_b, st_b, sched, state = run_slot_loop(program, params, pool,
                                              wl_b, 4)

    assert st_a.decode_steps == st_b.decode_steps
    assert state.streaming_bytes == engine.modeled_bytes["streaming_bytes"]
    for rid, ra in res_a.items():
        rb = res_b[rid]
        assert ra.topk_ids == rb.topk_ids
        assert ra.topk_scores == rb.topk_scores
        assert ra.tokens == rb.tokens


def test_slot_programs_implement_the_decode_protocol():
    """Both programs expose the full decode-side SlotProgram protocol —
    the contract run_slot_loop (and any future enc-dec/MoE program)
    relies on."""
    from repro.serving.engine import LMSlotProgram, SlotProgram
    from repro.serving.retrieval import RetrievalProgram
    for prog_cls in (LMSlotProgram, RetrievalProgram):
        for method in ("prefill", "check_admit", "init_state",
                       "reset_slots", "insert", "step", "emit"):
            assert getattr(prog_cls, method) is not getattr(
                SlotProgram, method, None), (prog_cls, method)
