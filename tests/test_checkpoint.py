"""Checkpointing: roundtrip, atomicity, corruption recovery, keep-N,
async writes, trainer crash/resume equivalence, elastic re-shard."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _state(v=1.0):
    return {"params": {"w": jnp.full((4, 3), v), "b": jnp.arange(3.0)},
            "opt": ({"mu": jnp.ones(2)}, jnp.asarray(7))}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(10, _state(2.0), extra={"data": {"cursor": 5}})
    restored, step, extra = ck.restore_latest(_state(0.0))
    assert step == 10 and extra["data"]["cursor"] == 5
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 2.0)


def test_restore_skips_corrupt_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1.0))
    ck.save(2, _state(2.0))
    # corrupt the newest checkpoint
    with open(os.path.join(ck._step_dir(2), "arrays.npz"), "w") as f:
        f.write("garbage")
    restored, step, _ = ck.restore_latest(_state(0.0))
    assert step == 1
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 1.0)


def test_keep_n_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _state(float(s)))
    assert ck.all_steps() == [3, 4]


def test_async_write_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=True)
    ck.save(3, _state(3.0), block=False)
    ck.wait()
    restored, step, _ = ck.restore_latest(_state(0.0))
    assert step == 3


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((2, 2))})
    restored, step, _ = ck.restore_latest({"w": jnp.zeros((3, 3))})
    assert restored is None and step == -1


def test_no_checkpoint_returns_none(tmp_path):
    ck = Checkpointer(str(tmp_path))
    restored, step, extra = ck.restore_latest(_state())
    assert restored is None and step == -1 and extra == {}


def test_trainer_crash_resume_equivalence(tmp_path):
    """Training N steps straight == training k steps, crashing, resuming.

    The core fault-tolerance guarantee: bitwise-identical final params —
    AND an identical logged history: `history` rides in the checkpoint's
    `extra`, so a resumed run returns the FULL curve, not just the
    post-crash tail (the Trainer bug ISSUE 8 fixed).
    """
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import BatchIterator
    from repro.train.trainer import Trainer

    rng = np.random.default_rng(0)
    X = rng.normal(size=(64, 8)).astype(np.float32)
    Y = (X @ rng.normal(size=(8, 1))).astype(np.float32)

    def loss_fn(params, batch):
        x, y = batch
        pred = x @ params["w"]
        return jnp.mean((pred - y) ** 2), {}

    def make(ckdir, every):
        tc = TrainConfig(steps=12, learning_rate=0.05, optimizer="adam",
                         checkpoint_every=every, warmup_steps=0,
                         grad_clip_norm=0.0)
        it = BatchIterator([X, Y], 16, seed=1)
        params = {"w": jnp.zeros((8, 1))}
        return Trainer(loss_fn, params, tc, it, checkpoint_dir=ckdir,
                       make_batch=lambda a: (jnp.asarray(a[0]),
                                             jnp.asarray(a[1])))

    # straight run
    t1 = make(str(tmp_path / "a"), every=100)
    r1 = t1.run(steps=12, log_every=2)
    # crashed run: stop at 6 (checkpointed), then resume in a NEW trainer
    t2 = make(str(tmp_path / "b"), every=6)
    t2.run(steps=6, log_every=2)
    t3 = make(str(tmp_path / "b"), every=6)
    r3 = t3.run(steps=12, log_every=2)
    np.testing.assert_allclose(np.asarray(t1.state.params["w"]),
                               np.asarray(t3.state.params["w"]),
                               rtol=1e-6)
    # the FULL history survives the kill: pre-crash entries restored
    # from the checkpoint, post-resume entries appended after them
    assert [h["step"] for h in r3["history"]] == \
        [h["step"] for h in r1["history"]] == [2, 4, 6, 8, 10, 12]
    np.testing.assert_allclose(
        [h["loss"] for h in r3["history"]],
        [h["loss"] for h in r1["history"]], rtol=1e-6)


def test_elastic_restore_applies_sharding(tmp_path):
    """Restore may apply any sharding — world-size change re-shards."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.arange(8.0)})
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, step, _ = ck.restore_latest({"w": jnp.zeros(8)},
                                          sharding=sharding)
    assert step == 1
    assert restored["w"].sharding == sharding
