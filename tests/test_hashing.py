"""Hash substrate: ranges, determinism, independence, de-duplication."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import hashing


@given(st.integers(1, 10_000), st.integers(1, 8), st.integers(16, 4096),
       st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_double_hash_range_and_determinism(n_ids, k, m, seed):
    k = min(k, m)
    ids = jnp.arange(min(n_ids, 256))
    h1 = np.asarray(hashing.double_hash(ids, k, m, seed))
    h2 = np.asarray(hashing.double_hash(ids, k, m, seed))
    assert h1.shape == (ids.shape[0], k)
    assert (h1 >= 0).all() and (h1 < m).all()
    np.testing.assert_array_equal(h1, h2)


def test_double_hash_seeds_differ():
    ids = jnp.arange(512)
    a = np.asarray(hashing.double_hash(ids, 4, 1024, seed=0))
    b = np.asarray(hashing.double_hash(ids, 4, 1024, seed=1))
    assert (a != b).mean() > 0.9


def test_double_hash_uniformity():
    """Projected ids should spread ~uniformly over [0, m)."""
    m = 64
    h = np.asarray(hashing.double_hash(jnp.arange(20_000), 2, m, seed=3))
    counts = np.bincount(h.reshape(-1), minlength=m)
    expected = h.size / m
    chi2 = ((counts - expected) ** 2 / expected).sum()
    # dof=63; 3x dof is a very loose bound that catches gross bias
    assert chi2 < 3 * m


def test_hash_matrix_no_row_duplicates():
    H = np.asarray(hashing.make_hash_matrix(5000, 6, 300, seed=1))
    dups = sum(len(r) - len(set(r)) for r in H)
    assert dups == 0
    assert H.min() >= 0 and H.max() < 300


def test_hash_matrix_np_strict():
    H = hashing.make_hash_matrix_np(2000, 8, 64, seed=2)
    for r in H:
        assert len(set(r)) == 8


def test_hash_matrix_np_matches_range():
    H = hashing.make_hash_matrix_np(100, 3, 10, seed=0)
    assert H.shape == (100, 3) and H.min() >= 0 and H.max() < 10


def test_k_greater_than_m_rejected():
    with pytest.raises(ValueError):
        hashing.make_hash_matrix(10, 5, 3)
    with pytest.raises(ValueError):
        hashing.make_hash_matrix_np(10, 5, 3)


def test_hash_indices_matrix_vs_onthefly_paths():
    ids = jnp.array([0, 5, 99])
    H = hashing.make_hash_matrix(100, 4, 32, seed=7)
    via_matrix = hashing.hash_indices(ids, k=4, m=32, seed=7,
                                      hash_matrix=H)
    np.testing.assert_array_equal(np.asarray(via_matrix),
                                  np.asarray(H)[np.asarray(ids)])
