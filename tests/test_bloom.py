"""Bloom embedding core: Eq. 1 encoding, Eq. 2/3 recovery, and the
no-false-negative property the paper inherits from Bloom filters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import bloom, losses
from repro.core.bloom import BloomSpec


def _spec(d=500, m=120, k=4, seed=0):
    return BloomSpec(d=d, m=m, k=k, seed=seed)


def test_encode_binary_and_bounded():
    spec = _spec()
    p = jnp.array([[1, 2, 3, -1], [7, -1, -1, -1]])
    u = np.asarray(bloom.encode(spec, p))
    assert u.shape == (2, spec.m)
    assert set(np.unique(u)) <= {0.0, 1.0}
    # at most c*k bits, at least k bits (if any item)
    assert u[0].sum() <= 3 * spec.k and u[0].sum() >= spec.k
    assert u[1].sum() <= spec.k


def test_encode_empty_set_is_zero():
    spec = _spec()
    u = np.asarray(bloom.encode(spec, jnp.full((1, 4), -1)))
    assert u.sum() == 0


@given(st.integers(2, 60), st.integers(1, 6), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_no_false_negatives(c, k, seed):
    """Bloom filters answer membership with 100% recall (paper Sec. 3.1):
    every encoded item must receive the MAXIMUM possible recovery score."""
    rng = np.random.default_rng(seed)
    d, m = 400, 150
    k = min(k, m)
    spec = BloomSpec(d=d, m=m, k=k, seed=seed)
    items = rng.choice(d, size=min(c, d), replace=False)
    p = jnp.asarray(items)[None, :]
    u = bloom.encode(spec, p)
    # log(u + eps): bits set -> ~0, unset -> very negative
    log_v = jnp.log(jnp.clip(u, 1e-12, 1.0))
    scores = np.asarray(bloom.decode_scores(spec, log_v, chunk=64))[0]
    top = scores.max()
    for it in items:
        assert scores[it] == pytest.approx(top)  # all-bits-set => max score


def test_decode_topk_matches_full_argsort():
    spec = _spec(d=300, m=100, k=3)
    key = jax.random.PRNGKey(1)
    logp = jax.nn.log_softmax(jax.random.normal(key, (4, spec.m)))
    full = np.asarray(bloom.decode_scores(spec, logp, chunk=77))
    v, i = bloom.decode_topk(spec, logp, topk=10, chunk=77)
    v, i = np.asarray(v), np.asarray(i)
    for b in range(4):
        order = np.argsort(-full[b], kind="stable")[:10]
        np.testing.assert_allclose(np.sort(v[b])[::-1], v[b], rtol=1e-6)
        np.testing.assert_allclose(full[b][i[b]], v[b], rtol=1e-5)
        assert set(np.round(full[b][order], 5)) == set(np.round(v[b], 5))


def test_decode_topk_unroll_equals_scan():
    spec = _spec(d=300, m=100, k=3)
    logp = jax.nn.log_softmax(
        jax.random.normal(jax.random.PRNGKey(2), (2, spec.m)))
    v1, i1 = bloom.decode_topk(spec, logp, topk=7, chunk=64, unroll=False)
    v2, i2 = bloom.decode_topk(spec, logp, topk=7, chunk=64, unroll=True)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


def test_encode_dense_matches_sparse_encode():
    spec = _spec(d=80, m=40, k=3)
    p = jnp.array([[3, 10, 50, -1]])
    x = np.zeros((1, 80), np.float32)
    x[0, [3, 10, 50]] = 1.0
    u1 = np.asarray(bloom.encode(spec, p))
    u2 = np.asarray(bloom.encode_dense(spec, jnp.asarray(x)))
    np.testing.assert_allclose(u1, u2)


def test_identity_spec_roundtrip():
    spec = bloom.identity_spec(50)
    p = jnp.array([[4, 7, -1]])
    u = np.asarray(bloom.encode(spec, p))
    assert u[0, 4] == 1 and u[0, 7] == 1 and u.sum() == 2


def test_recover_probabilities_normalized():
    spec = _spec(d=100, m=64, k=2)
    v_hat = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (3, 64)))
    probs = np.asarray(bloom.recover_probabilities(spec, v_hat))
    np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)
    assert (probs >= 0).all()


def test_ranking_preserved_under_monotone_eq2_eq3():
    """Eq. 2 (product) and Eq. 3 (neg-log-sum) give identical rankings."""
    spec = _spec(d=200, m=80, k=3)
    v_hat = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(5), (80,)))
    log_v = jnp.log(v_hat)
    s3 = np.asarray(bloom.decode_scores(spec, log_v, chunk=64))
    idx = spec.indices_for(jnp.arange(200))
    s2 = np.asarray(jnp.prod(v_hat[idx], axis=-1))
    # Eq. 3 == log(Eq. 2) pointwise => identical ranking (up to fp ties)
    np.testing.assert_allclose(s3, np.log(s2), rtol=1e-4, atol=1e-5)


def test_spec_validation():
    with pytest.raises(ValueError):
        BloomSpec(d=10, m=20, k=1)
    with pytest.raises(ValueError):
        BloomSpec(d=10, m=5, k=6)
