"""Tests for the §Perf hillclimb features: adafactor, bf16 score chains,
causal_skip config path, one-shot param casting, optimized-mesh specs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.launch import steps as steps_lib
from repro.models import attention as A
from repro.models import transformer as tf
from repro.optim import optimizers as opt

KEY = jax.random.PRNGKey(0)


def test_adafactor_state_is_factored_and_small():
    tx = opt.scale_by_adafactor()
    params = {"big": jnp.zeros((64, 128)), "vec": jnp.zeros((32,))}
    st = tx.init(params)
    assert st["s"]["big"]["nu"]["vr"].shape == (64,)
    assert st["s"]["big"]["nu"]["vc"].shape == (128,)
    assert st["s"]["big"]["mu"].dtype == jnp.bfloat16
    assert st["s"]["vec"]["nu"]["v"].shape == (32,)
    # state bytes << adam's 2x fp32
    n_state = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(st["s"]))
    n_adam = 2 * sum(p.size * 4 for p in jax.tree.leaves(params))
    assert n_state < 0.35 * n_adam


def test_adafactor_trains_the_lm():
    cfg = configs.get_smoke_config("qwen3-4b")
    tc = TrainConfig(optimizer="adafactor", learning_rate=3e-3,
                     grad_clip_norm=1.0, warmup_steps=0)
    step, tx = steps_lib.make_train_step(cfg, tc)
    params = tf.lm_init(KEY, cfg)
    opt_state = tx.init(params)
    toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab)
    losses = []
    jstep = jax.jit(step)
    for _ in range(15):
        params, opt_state, m = jstep(params, opt_state, {"tokens": toks})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_bf16_scores_close_to_f32():
    B, S, KV, G, hd = 2, 16, 2, 2, 8
    q = jax.random.normal(KEY, (B, S, KV, G, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd),
                          jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_bf = A.chunked_attention(q, k, v, causal=True, chunk_k=4, q_pos=pos,
                               kv_pos=pos, bf16_scores=True)
    o_f32 = A.naive_attention(q.astype(jnp.float32),
                              k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True,
                              q_pos=pos, kv_pos=pos)
    diff = float(jnp.abs(o_bf.astype(jnp.float32) - o_f32).max())
    assert diff < 3e-2, diff


def test_bf16_scores_model_loss_close():
    cfg = configs.get_smoke_config("granite-8b", dtype="float32")
    cfg_b = dataclasses.replace(cfg, attn_bf16_scores=True)
    params = tf.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    l1, _ = tf.lm_loss_fn(params, cfg, {"tokens": toks})
    l2, _ = tf.lm_loss_fn(params, cfg_b, {"tokens": toks})
    assert float(l1) == pytest.approx(float(l2), rel=3e-2)


def test_causal_skip_model_equivalence():
    cfg = configs.get_smoke_config("phi3-mini-3.8b", dtype="float32")
    cfg_cs = dataclasses.replace(cfg, causal_skip=True, attn_chunk_q=8,
                                 attn_chunk_k=8)
    params = tf.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    l1, _ = tf.lm_loss_fn(params, cfg, {"tokens": toks})
    l2, _ = tf.lm_loss_fn(params, cfg_cs, {"tokens": toks})
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)


def test_cast_params_for_compute_only_matrices():
    cfg = configs.get_smoke_config("qwen3-4b", dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4), jnp.float32),
              "scale": jnp.ones((4,), jnp.float32),
              "idx": jnp.zeros((4, 4), jnp.int32)}
    out = steps_lib.cast_params_for_compute(params, cfg)
    assert out["w"].dtype == jnp.bfloat16
    assert out["scale"].dtype == jnp.float32   # 1-D stays fp32
    assert out["idx"].dtype == jnp.int32       # ints untouched


def test_adafactor_opt_state_specs():
    from repro.launch.sharding import opt_state_pspecs, param_pspecs
    from jax.sharding import PartitionSpec as P

    class _FakeDist:
        n_model = 16
        model_axis = "model"

    cfg = configs.get_config("qwen3-4b")
    init = steps_lib.init_fn_for(cfg)
    params = jax.eval_shape(init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = param_pspecs(cfg, params, _FakeDist())
    tx = opt.make_optimizer("adafactor", 1e-3)
    opt_sds = jax.eval_shape(tx.init, params)
    ospecs = opt_state_pspecs(opt_sds, pspecs)
    # embed moment mu inherits the vocab-sharded spec
    mu_spec = ospecs[0]["s"]["io"]["embed"]["mu"]
    assert mu_spec == pspecs["io"]["embed"]
    vr_spec = ospecs[0]["s"]["io"]["embed"]["nu"]["vr"]
    assert len(vr_spec) == 1
