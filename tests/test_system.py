"""End-to-end behaviour tests for the paper's system.

These are the integration gates: (1) a Bloom-embedded recommender must
actually learn (beat random by a wide margin) on sparse data, (2) the
Bloom LM path must train, (3) serving must produce recovered-vocab tokens,
(4) the full train driver must be crash-recoverable.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.core.alternatives import BloomIO
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_recsys
from repro.models import recommender as rec
from repro.train import metrics as M
from repro.train.trainer import Trainer


def _train_recommender(emb, data, steps=150, hidden=(64, 64), lr=2e-3):
    key = jax.random.PRNGKey(0)
    params = rec.recommender_init(key, emb, list(hidden))
    loss_fn = lambda p, b: (rec.recommender_loss(p, emb, b[0], b[1]), {})
    it = BatchIterator(list(data.train()), 64, seed=1)
    tc = TrainConfig(steps=steps, learning_rate=lr, optimizer="adam",
                     warmup_steps=0, checkpoint_every=0,
                     grad_clip_norm=0.0)
    tr = Trainer(loss_fn, params, tc, it,
                 make_batch=lambda a: (jnp.asarray(a[0]),
                                       jnp.asarray(a[1])))
    tr.run(steps=steps)
    return tr.state.params


def test_bloom_recommender_learns():
    data = make_recsys(n=1200, d=500, mean_items=10, seed=0)
    emb = BloomIO.build(d=500, m=150, k=4)
    params = _train_recommender(emb, data)
    p_te, q_te = data.test()
    scores = np.asarray(rec.recommender_scores(params, emb,
                                               jnp.asarray(p_te)))
    mapv = M.mean_average_precision(scores, q_te, p_te)
    random_map = M.mean_average_precision(
        np.random.default_rng(0).normal(size=scores.shape), q_te, p_te)
    assert mapv > 5 * random_map, (mapv, random_map)
    assert mapv > 0.03


def test_lm_smoke_training_reduces_loss():
    from repro.launch.train import run
    params, history = run("qwen1.5-0.5b", steps=40, batch=4, seq=32,
                          ckpt_dir=None, log_every=5)
    losses = [h["loss"] for h in history]
    assert losses[-1] < losses[0] - 0.2, losses


def test_serve_driver_generates_tokens():
    from repro.launch.serve import run
    toks = run("qwen1.5-0.5b", batch=2, prompt_len=12, gen=5)
    assert toks.shape == (2, 5)
    cfg = configs.get_smoke_config("qwen1.5-0.5b")
    assert (toks >= 0).all() and (toks < cfg.vocab).all()


def test_train_driver_crash_and_resume(tmp_path):
    """Kill the driver mid-run via --fault-at, rerun, expect completion."""
    ck = str(tmp_path / "ck")
    from conftest import subprocess_env
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1.5-0.5b", "--steps", "16", "--batch", "2", "--seq", "16",
           "--ckpt", ck]
    env = subprocess_env()
    r1 = subprocess.run(cmd + ["--fault-at", "10"], capture_output=True,
                        text=True, env=env, cwd="/root/repo")
    assert r1.returncode != 0 and "induced fault" in r1.stderr
    r2 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                        cwd="/root/repo")
    assert r2.returncode == 0, r2.stderr
    assert "resumed from step" in r2.stdout
    assert "trained" in r2.stdout


def test_grad_accumulation_matches_full_batch():
    """microbatch=2 grad accumulation == one big batch (linear model)."""
    from repro.train.trainer import make_train_step, make_optimizer
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(16, 1)).astype(np.float32))

    def loss_fn(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2), {}

    tc = TrainConfig(optimizer="sgd", learning_rate=0.1, momentum=0.0,
                     grad_clip_norm=0.0, warmup_steps=0)
    tx = make_optimizer(tc)
    p0 = {"w": jnp.zeros((4, 1))}

    full = make_train_step(loss_fn, tx, microbatch=0, donate=False)
    acc = make_train_step(loss_fn, tx, microbatch=2, donate=False)
    p1, _, _ = full(p0, tx.init(p0), (X, Y))
    p2, _, _ = acc(p0, tx.init(p0), (X, Y))
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5)
