"""Unit tests for the overload policy layer (DESIGN.md §14).

All JAX-free: admission.py is pure python by design (like control.py),
so the policy math — shed decisions, the pressure ladder, the stage
width contract — and the promoted queue-integrity exceptions are pinned
here without touching a device.  The engine-level behaviour (stage jit
swaps, bit-identical served tokens, the chaos drill) lives in
test_serving.py / test_retrieval.py / test_serving_multihost.py.
"""
import numpy as np
import pytest

from repro.serving import control as control_lib
from repro.serving.admission import (MAX_STAGE, SHED_DEADLINE,
                                     SHED_QUEUE_FULL, STAGE_MIN,
                                     STAGE_NARROW, STAGE_NORMAL,
                                     AdmissionPolicy, compute_sheds,
                                     plan_stage, pressure, slo_attainment,
                                     stage_topk)
from repro.serving.loadgen import LoadSpec, host_stream, overload_workload
from repro.serving.scheduler import (Request, RequestQueue,
                                     ShardedScheduler)


def _req(rid, arrival=0, home=0, max_gen=2, deadline=-1):
    return Request(rid=rid, prompt=np.zeros((2,), np.int32),
                   max_gen=max_gen, arrival_step=arrival, home=home,
                   deadline_step=deadline)


# ---------------------------------------------------------------------------
# AdmissionPolicy validation (LoadSpec-style: fail at construction)
# ---------------------------------------------------------------------------

def test_policy_validates_at_construction():
    AdmissionPolicy()                       # defaults are valid
    with pytest.raises(ValueError, match="max_queue_depth"):
        AdmissionPolicy(max_queue_depth=0)
    with pytest.raises(ValueError, match="pressure_window"):
        AdmissionPolicy(pressure_window=0)
    with pytest.raises(ValueError, match="degrade_lo"):
        AdmissionPolicy(degrade_lo=2.0, degrade_hi=1.0)
    with pytest.raises(ValueError, match="degrade_lo"):
        AdmissionPolicy(degrade_lo=0.0)
    with pytest.raises(ValueError, match="restore_below"):
        AdmissionPolicy(degrade_lo=0.5, restore_below=0.6)
    with pytest.raises(ValueError, match="max_stage"):
        AdmissionPolicy(max_stage=MAX_STAGE + 1)
    with pytest.raises(ValueError, match="degraded_topk"):
        AdmissionPolicy(degraded_topk=0)


# ---------------------------------------------------------------------------
# compute_sheds: the deterministic shed function
# ---------------------------------------------------------------------------

def test_deadline_sheds_only_past_deadline():
    pending = {1: (0, 0), 2: (1, 0), 3: (2, 1)}
    deadlines = {1: 4, 2: 9}                # rid 3 has no deadline
    pol = AdmissionPolicy()
    assert compute_sheds(pending, deadlines, now=4, policy=pol) == []
    assert compute_sheds(pending, deadlines, now=5, policy=pol) == \
        [(1, SHED_DEADLINE)]
    assert compute_sheds(pending, deadlines, now=50, policy=pol) == \
        [(1, SHED_DEADLINE), (2, SHED_DEADLINE)]


def test_queue_bound_keeps_fifo_first_per_home():
    # home 0 queues rids 1,2,5 (arrivals 0,1,2); home 1 queues 3,4
    pending = {1: (0, 0), 2: (1, 0), 5: (2, 0), 3: (0, 1), 4: (3, 1)}
    pol = AdmissionPolicy(max_queue_depth=2)
    sheds = compute_sheds(pending, {}, now=10, policy=pol)
    # the latest arrival of the over-bound home is shed; home 1 is at
    # its bound and keeps both
    assert sheds == [(5, SHED_QUEUE_FULL)]
    # a deadline shed frees a queue position BEFORE the bound applies
    sheds = compute_sheds(pending, {1: 3}, now=10, policy=pol)
    assert sheds == [(1, SHED_DEADLINE)]


def test_sheds_are_rid_sorted_and_pure():
    pending = {9: (5, 0), 4: (0, 0), 7: (1, 0)}
    pol = AdmissionPolicy(max_queue_depth=1)
    a = compute_sheds(pending, {9: 2}, now=6, policy=pol)
    b = compute_sheds(dict(reversed(pending.items())), {9: 2}, now=6,
                      policy=pol)
    assert a == b == [(7, SHED_QUEUE_FULL), (9, SHED_DEADLINE)]
    assert [rid for rid, _ in a] == sorted(rid for rid, _ in a)


# ---------------------------------------------------------------------------
# the degrade ladder
# ---------------------------------------------------------------------------

def test_stage_topk_width_contract():
    pol = AdmissionPolicy(degraded_topk=2)
    assert stage_topk(8, STAGE_NORMAL, pol) == 8
    assert stage_topk(8, STAGE_NARROW, pol) == 4
    assert stage_topk(8, STAGE_MIN, pol) == 2
    assert stage_topk(1, STAGE_NARROW, pol) == 1     # never below 1
    assert stage_topk(1, STAGE_MIN, pol) == 1        # capped at topk
    with pytest.raises(ValueError, match="unknown degrade stage"):
        stage_topk(8, MAX_STAGE + 1, pol)


def test_ladder_escalates_one_stage_per_tick_with_hysteresis():
    pol = AdmissionPolicy(pressure_window=2, degrade_lo=1.0,
                          degrade_hi=2.0, restore_below=0.5)
    # window not yet full: never move
    assert plan_stage([9.0], pol, STAGE_NORMAL) == STAGE_NORMAL
    # above hi the target is stage 2, but moves are one step per tick
    assert plan_stage([2.5, 2.5], pol, STAGE_NORMAL) == STAGE_NARROW
    assert plan_stage([2.5, 2.5], pol, STAGE_NARROW) == STAGE_MIN
    assert plan_stage([2.5, 2.5], pol, STAGE_MIN) == STAGE_MIN
    # between restore_below and lo: hold (hysteresis, no flap)
    assert plan_stage([0.8, 0.8], pol, STAGE_NARROW) == STAGE_NARROW
    # at/below restore_below: restore one stage per tick
    assert plan_stage([0.4, 0.4], pol, STAGE_MIN) == STAGE_NARROW
    assert plan_stage([0.4, 0.4], pol, STAGE_NARROW) == STAGE_NORMAL
    # max_stage=0 disables the ladder outright
    off = AdmissionPolicy(max_stage=0)
    assert plan_stage([99.0] * 4, off, STAGE_NORMAL) == STAGE_NORMAL


def test_pressure_and_slo_arithmetic():
    assert pressure(0, 8) == 0.0
    assert pressure(8, 8) == 1.0
    assert pressure(3, 0) == 3.0             # all hosts dead: max live=1
    assert slo_attainment(9, 12) == 0.75
    assert slo_attainment(0, 0) == 0.0


# ---------------------------------------------------------------------------
# overload_workload: validated, pure in (seed, host), ramp baked in
# ---------------------------------------------------------------------------

def test_overload_workload_validates_and_compresses():
    spec = LoadSpec(n_requests=6, vocab=64, rate=0.7, seed=3)
    with pytest.raises(ValueError, match="surge_start"):
        overload_workload(spec, 2, surge_start=-1, surge_factor=2)
    with pytest.raises(ValueError, match="surge_factor"):
        overload_workload(spec, 2, surge_start=0, surge_factor=1)
    with pytest.raises(ValueError, match="deadline_slack"):
        overload_workload(spec, 2, surge_start=0, surge_factor=2,
                          deadline_slack=0)

    s0 = 4
    wl = overload_workload(spec, 2, surge_start=s0, surge_factor=3,
                           deadline_slack=5)
    plain = [host_stream(spec, h, 2) for h in range(2)]
    for hosts, base in zip(wl, plain):
        for r, b in zip(hosts, base):
            # pre-surge arrivals untouched; later ones 3x-compressed
            want = (b.arrival_step if b.arrival_step < s0
                    else s0 + (b.arrival_step - s0) // 3)
            assert r.arrival_step == want
            assert r.deadline_step == r.arrival_step + 5
            assert r.rid == b.rid and r.home == b.home
    # pure in (seed, host): a replay is identical
    again = overload_workload(spec, 2, surge_start=s0, surge_factor=3,
                              deadline_slack=5)
    assert [(r.rid, r.arrival_step, r.deadline_step)
            for hs in wl for r in hs] == \
        [(r.rid, r.arrival_step, r.deadline_step)
         for hs in again for r in hs]
    # no deadline_slack -> no deadlines
    free = overload_workload(spec, 2, surge_start=0, surge_factor=2)
    assert all(r.deadline_step < 0 for hs in free for r in hs)


# ---------------------------------------------------------------------------
# promoted exceptions on the admission path (the PR 10 bugfix satellite:
# bare asserts vanish under ``python -O`` — queue integrity must not)
# ---------------------------------------------------------------------------

def test_push_rejects_bad_home_duplicate_and_readmission():
    sched = ShardedScheduler(n_hosts=2, slots_per_host=1, gossip_delay=0)
    sched.push(_req(0, home=0))
    with pytest.raises(ValueError, match="outside"):
        sched.push(_req(1, home=5))
    with pytest.raises(ValueError, match="pushed twice"):
        sched.push(_req(0, home=0))
    sched.begin_step(0)
    admitted = sched.admit(0)
    assert [r.rid for r in admitted] == [0]
    with pytest.raises(ValueError, match="already admitted"):
        sched.push(_req(0, home=0))


def test_admit_requires_begin_step_when_policy_enabled():
    sched = ShardedScheduler(n_hosts=1, slots_per_host=1, gossip_delay=0,
                             admission_policy=AdmissionPolicy())
    sched.push(_req(0))
    with pytest.raises(RuntimeError, match="begin_step"):
        sched.admit(0)
    # without policy or compaction the old implicit begin_step stands
    plain = ShardedScheduler(n_hosts=1, slots_per_host=1, gossip_delay=0)
    plain.push(_req(0))
    assert [r.rid for r in plain.admit(0)] == [0]


def test_request_queue_remove_raises_on_unknown_rid():
    q = RequestQueue([_req(0), _req(1)])
    assert [r.rid for r in q.remove([1])] == [1]
    with pytest.raises(RuntimeError, match=r"\[1, 7\]"):
        q.remove([0, 1, 7])
    assert len(q) == 1                      # failed remove mutated nothing


def test_commit_sheds_raises_on_not_queued_rid():
    state = control_lib.ControlState.fresh(n_hosts=1, slots_per_host=2)
    state.pending[3] = (0, 0)
    state.deadlines[3] = 9
    control_lib.commit_sheds(state, [3])
    assert 3 not in state.pending and 3 not in state.deadlines
    with pytest.raises(RuntimeError, match="not queued"):
        control_lib.commit_sheds(state, [3])


def test_arrive_twice_raises_in_apply_deltas():
    state = control_lib.ControlState.fresh(n_hosts=1, slots_per_host=1)
    d = control_lib.Delta(control_lib.ARRIVE, 0, 0, 7, slot=-1)
    state = control_lib.apply_deltas(state, [d])
    with pytest.raises(RuntimeError, match="arrived twice"):
        control_lib.apply_deltas(state, [d])


def test_arrive_delta_replicates_deadline_into_digest():
    """The ARRIVE slot lane carries deadline_step: two states differing
    only in a deadline must produce different control digests (the
    divergence check covers the shed inputs)."""
    mk = lambda dl: control_lib.apply_deltas(
        control_lib.ControlState.fresh(n_hosts=1, slots_per_host=1),
        [control_lib.Delta(control_lib.ARRIVE, 0, 0, 7, slot=dl)])
    a, b, c = mk(5), mk(6), mk(5)
    assert control_lib.control_digest(a) == control_lib.control_digest(c)
    assert control_lib.control_digest(a) != control_lib.control_digest(b)
    assert a.deadlines == {7: 5}
