"""Sharding rules: spec validity for every param of every arch, and
numerical equivalence of the distributed code path on a 1x1 mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import (DistContext, batch_pspecs, cache_pspecs,
                                   opt_state_pspecs, param_pspecs)
from repro.launch import steps as steps_lib

KEY = jax.random.PRNGKey(0)


class _FakeDist:
    """DistContext-shaped probe with a 16-way model axis for rule checks."""
    n_model = 16
    model_axis = "model"


@pytest.mark.parametrize("arch", list(configs.ARCH_NAMES))
def test_param_specs_cover_all_leaves_full_config(arch):
    cfg = configs.get_config(arch)
    init = steps_lib.init_fn_for(cfg)
    params = jax.eval_shape(init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_pspecs(cfg, params, _FakeDist())
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        assert len(spec) == len(leaf.shape), (path, leaf.shape, spec)
        # every sharded dim must divide by the 16-way model axis
        for dim, ax in zip(leaf.shape, spec):
            if ax == "model":
                assert dim % 16 == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["qwen3-4b", "jamba-v0.1-52b"])
def test_big_weights_are_sharded(arch):
    """No tensor > 64 MiB (fp32) may stay fully replicated on the 16-way
    model axis — the memory-feasibility core of the TP layout."""
    cfg = configs.get_config(arch)
    init = steps_lib.init_fn_for(cfg)
    params = jax.eval_shape(init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_pspecs(cfg, params, _FakeDist())
    for (path, leaf), spec in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        size = np.prod(leaf.shape) * 4
        path_s = "/".join(str(getattr(x, "key", x)) for x in path)
        # kv projections replicate BY DESIGN when num_kv_heads < n_model
        # (GQA kv replication; they shard on TP<=kv meshes — see §Perf)
        if ("/wk" in path_s or "/wv" in path_s) and                 cfg.num_kv_heads % 16 != 0:
            continue
        if size > 64 * 2**20:
            assert any(ax == "model" for ax in spec), (path, leaf.shape)


def test_dist_path_matches_plain_path_numerically():
    """Running through DistContext on a trivial mesh must not change math."""
    cfg = configs.get_smoke_config("qwen3-4b", dtype="float32")
    from repro.models import transformer as tf
    mesh = make_local_mesh()          # (1, n_devices) == (1, 1) on CPU
    dist = DistContext(mesh)
    params = tf.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    with mesh:
        l_dist, _ = jax.jit(
            lambda p: tf.lm_loss_fn(p, cfg, {"tokens": toks}, dist=dist)
        )(params)
    l_plain, _ = tf.lm_loss_fn(params, cfg, {"tokens": toks})
    assert float(l_dist) == pytest.approx(float(l_plain), rel=1e-5)


def test_moe_ep_path_matches_dense_path_on_trivial_mesh():
    import dataclasses
    cfg = configs.get_smoke_config("olmoe-1b-7b", dtype="float32")
    cfg_ep = dataclasses.replace(
        cfg, moe_impl="ep",
        moe=dataclasses.replace(cfg.moe, num_experts=8, capacity_factor=8.0))
    cfg_dense = dataclasses.replace(
        cfg, moe_impl="dense",
        moe=dataclasses.replace(cfg.moe, num_experts=8, capacity_factor=8.0))
    from repro.models import moe as moe_lib
    params = moe_lib.moe_init(KEY, cfg_ep)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    mesh = make_local_mesh()
    dist = DistContext(mesh)
    with mesh:
        y_ep, aux_ep = jax.jit(
            lambda p, x: moe_lib.moe_apply(p, x, cfg_ep, dist))(params, x)
    y_d, aux_d = moe_lib.moe_apply(params, x, cfg_dense)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_d),
                               atol=1e-4)
    assert float(aux_ep) == pytest.approx(float(aux_d), rel=1e-4)


def test_batch_and_cache_specs():
    cfg = configs.get_config("qwen3-4b")
    shape = configs.SHAPE_BY_NAME["decode_32k"]
    mesh = make_local_mesh()
    dist = DistContext(mesh)
    batch = configs.input_specs(cfg, shape)
    bs = batch_pspecs(cfg, batch, dist)
    assert jax.tree.leaves(bs, is_leaf=lambda x: isinstance(x, P))
    caches = configs.cache_specs(cfg, shape)
    cs = cache_pspecs(cfg, caches, dist, shape.global_batch)
    for (path, leaf), spec in zip(
            jax.tree_util.tree_leaves_with_path(caches),
            jax.tree.leaves(cs, is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) == len(leaf.shape)


def test_opt_state_specs_mirror_params():
    from repro.train import trainer as trainer_lib
    from repro.configs.base import TrainConfig
    cfg = configs.get_smoke_config("granite-8b")
    from repro.models import transformer as tf
    params = jax.eval_shape(lambda k: tf.lm_init(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = param_pspecs(cfg, params, _FakeDist())
    tx = trainer_lib.make_optimizer(TrainConfig(optimizer="adamw"))
    opt_sds = jax.eval_shape(tx.init, params)
    ospecs = opt_state_pspecs(opt_sds, pspecs)
    # structure must match; adam mu subtree must carry param specs
    jax.tree.map(lambda s, o: None, opt_sds,
                 jax.tree.map(lambda _: 0, ospecs,
                              is_leaf=lambda x: isinstance(x, P)))
