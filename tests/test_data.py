"""Data generators + resumable pipeline."""
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.pipeline import BatchIterator, lm_batches


def test_recsys_statistics():
    data = synthetic.make_recsys(n=500, d=400, mean_items=10, seed=0)
    assert data.p_in.shape[0] == 500
    # every instance has >= 1 input and >= 1 output item
    assert (data.p_in[:, 0] >= 0).all()
    assert (data.q_out[:, 0] >= 0).all()
    # density in the sparse regime the paper studies
    density = data.X_in.nnz / (500 * 400)
    assert 1e-3 < density < 0.2
    # input/output items within range
    assert data.p_in.max() < 400 and data.q_out.max() < 400


def test_recsys_cooccurrence_structure():
    """Latent-factor data must have more co-occurrence than shuffled data."""
    from repro.core.cbe import cooccurrence_stats
    data = synthetic.make_recsys(n=800, d=300, mean_items=8, seed=1)
    pct, rho = cooccurrence_stats(data.X_in)
    assert pct > 0.5  # co-occurring pairs exist


def test_classification_generator():
    p, labels, n_train, X = synthetic.make_classification(
        n=200, d=500, n_classes=5, seed=0)
    assert p.shape[0] == 200 and labels.shape == (200,)
    assert labels.min() >= 0 and labels.max() < 5
    assert 0 < n_train < 200


def test_sessions_generator():
    seqs, n_train = synthetic.make_sessions(n_sessions=100, d=200, seed=0)
    assert seqs.shape[0] == 100
    assert (seqs[:, 0] >= 0).all()          # at least one item
    assert (seqs[:, 1] >= 0).all()          # min length 2


def test_token_stream_zipf():
    s = synthetic.make_token_stream(50_000, vocab=1000, seed=0)
    counts = np.bincount(s, minlength=1000)
    # zipf: top token much more frequent than median
    assert counts.max() > 20 * max(np.median(counts), 1)


def test_iterator_determinism_and_resume():
    X = np.arange(100)[:, None]
    it1 = BatchIterator([X], 10, seed=3)
    seq1 = [it1.__next__()[0].copy() for _ in range(15)]

    it2 = BatchIterator([X], 10, seed=3)
    for _ in range(7):
        next(it2)
    state = it2.state()
    it3 = BatchIterator([X], 10, seed=0)
    it3.restore(state)
    for i in range(7, 15):
        np.testing.assert_array_equal(next(it3)[0], seq1[i])


def test_iterator_host_sharding_partitions_data():
    X = np.arange(100)[:, None]
    a = BatchIterator([X], 5, host_id=0, host_count=2)
    b = BatchIterator([X], 5, host_id=1, host_count=2)
    assert a.n == 50 and b.n == 50
    assert set(a.arrays[0].ravel()) | set(b.arrays[0].ravel()) == set(
        range(100))
    assert not (set(a.arrays[0].ravel()) & set(b.arrays[0].ravel()))


def test_lm_batches_windows():
    s = np.arange(100, dtype=np.int32)
    w = lm_batches(s, batch=4, seq_len=9)
    assert w.shape == (10, 10)
    np.testing.assert_array_equal(w[0], np.arange(10))
