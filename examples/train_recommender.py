"""Paper-style experiment: BE vs Baseline on an MSD-like task.

Reproduces the paper's core claim end to end on synthetic data matched to
the MSD statistics: at m/d = 0.2 the Bloom-embedded model keeps >= ~90% of
the baseline MAP while training ~2-3x faster (Figs. 1 & 3).

Run:  PYTHONPATH=src python examples/train_recommender.py [--quick]
"""
import argparse

from benchmarks.common import baseline_embedding, run_task
from repro.configs.paper_tasks import PAPER_TASKS
from repro.core.alternatives import BloomIO


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="MSD", choices=list(PAPER_TASKS))
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    steps = 80 if args.quick else 200
    scale = 0.4 if args.quick else 1.0

    t = PAPER_TASKS[args.task]
    base = run_task(args.task, baseline_embedding(t.d), steps=steps,
                    scale=scale)
    print(f"[{args.task}] baseline:  score={base['score']:.4f}  "
          f"train={base['train_time']:.1f}s eval={base['eval_time']*1e3:.0f}ms")

    for ratio in (0.5, 0.2, 0.1):
        m = int(t.d * ratio)
        be = run_task(args.task, BloomIO.build(d=t.d, m=m, k=4),
                      steps=steps, scale=scale)
        print(f"[{args.task}] BE m/d={ratio:.1f}: "
              f"score={be['score']:.4f} "
              f"(S_i/S_0={be['score']/max(base['score'],1e-9):.3f})  "
              f"train={be['train_time']:.1f}s "
              f"(T_i/T_0={be['train_time']/base['train_time']:.2f})  "
              f"eval={be['eval_time']*1e3:.0f}ms")


if __name__ == "__main__":
    main()
