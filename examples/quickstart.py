"""Quickstart: Bloom embeddings in 60 seconds.

1. Bloom-encode sparse item sets (paper Eq. 1),
2. train a tiny recommender entirely in the compressed m-space,
3. recover a ranking over the original d items (paper Eq. 3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BloomSpec, encode, decode_topk
from repro.core.alternatives import BloomIO
from repro.data.synthetic import make_recsys
from repro.data.pipeline import BatchIterator
from repro.models import recommender as rec
from repro.optim import optimizers as opt
from repro.train import metrics as M

# --- 1. the embedding itself -------------------------------------------
d, m, k = 5000, 1000, 4            # 5x compression (m/d = 0.2)
spec = BloomSpec(d=d, m=m, k=k)
items = jnp.array([[17, 423, 4999, -1]])      # one padded item set
u = encode(spec, items)
print(f"encoded {int((items >= 0).sum())} items -> {int(u.sum())} of {m} "
      f"bits set (k={k} hashes/item)")

# --- 2. train a recommender in m-space ----------------------------------
data = make_recsys(n=2000, d=d, mean_items=8, seed=0)
emb = BloomIO.build(d=d, m=m, k=k)
params = rec.recommender_init(jax.random.PRNGKey(0), emb, [128, 128])
tx = opt.make_optimizer("adam", 2e-3)
state = tx.init(params)


@jax.jit
def step(params, state, p, q):
    g = jax.grad(lambda pr: rec.recommender_loss(pr, emb, p, q))(params)
    upd, state = tx.update(g, state, params)
    return opt.apply_updates(params, upd), state


it = BatchIterator(list(data.train()), 64, seed=0)
for i in range(150):
    p, q = next(it)
    params, state = step(params, state, jnp.asarray(p), jnp.asarray(q))

# --- 3. recover rankings over the ORIGINAL items -------------------------
p_te, q_te = data.test()
scores = np.asarray(rec.recommender_scores(params, emb, jnp.asarray(p_te)))
print(f"test MAP = {M.mean_average_precision(scores, q_te, p_te):.4f} "
      f"(random ~{1/d:.5f}) with a {m}/{d} = {m/d:.0%} sized model")

# direct Eq.3 top-k recovery from a probability vector:
logp = jax.nn.log_softmax(jax.random.normal(jax.random.PRNGKey(1), (1, m)))
vals, ids = decode_topk(spec, logp, topk=5)
print("top-5 recovered item ids from an m-dim softmax:", np.asarray(ids[0]))
