"""End-to-end driver: train a ~100M-parameter Bloom-IO LM for a few
hundred steps on a synthetic Zipf token stream.

The model is a qwen-style decoder (12L, d_model=768, GQA 12/4) with the
paper's technique at the IO boundary: vocab 50,304 compressed to m=10,240
(m/d ~= 0.2, k=4).  Checkpoint/resume, LR schedule, grad clipping — the
full production train loop at laptop scale.

Run:  PYTHONPATH=src python examples/train_lm_100m.py \
          [--steps 300] [--ckpt /tmp/ckpt_100m]
"""
import argparse
import dataclasses

from repro import configs
from repro.configs.base import BloomConfig, ModelConfig
from repro.launch import train as train_driver


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="bloom-lm-100m",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab=50_304,
        dtype="float32",          # CPU example; bf16 on TPU
        attn_chunk_q=64,
        attn_chunk_k=64,
        remat="none",
        bloom=BloomConfig(enabled=True, m_ratio=0.2, k=4),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/ckpt_bloom_lm_100m")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"model: {cfg.param_count()/1e6:.0f}M params "
          f"(dense-IO equivalent: "
          f"{dataclasses.replace(cfg, bloom=BloomConfig(enabled=False)).param_count()/1e6:.0f}M) "
          f"m_vocab={cfg.m_vocab} of vocab={cfg.vocab}")

    # monkey-patch the arch registry so the driver picks up our config
    configs.ARCH_MODULES["bloom-lm-100m"] = type(
        "M", (), {"ARCH": "bloom-lm-100m",
                  "config": staticmethod(lambda bloom=True: cfg),
                  "smoke": staticmethod(lambda: cfg)})
    params, history = train_driver.run(
        "bloom-lm-100m", steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt, log_every=10, learning_rate=6e-4)
    if history:
        print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
              f"over {args.steps} steps")


if __name__ == "__main__":
    main()
