"""CBE demo (paper Sec. 6): redirect Bloom collisions onto co-occurring
item pairs and measure the gain over plain BE.

Run:  PYTHONPATH=src python examples/cbe_cooccurrence.py
"""
from benchmarks.bench_table5_cbe import run

for row in run(points=(("MSD", 0.1),), steps=150, scale=0.5):
    print(f"task={row['task']} m/d={row['m_over_d']}  "
          f"input co-occurrence: {row['cooc_pct_in']:.1f}% of pairs "
          f"(rho={row['cooc_rho_in']:.2e})")
    print(f"  BE  S_i/S_0 = {row['be_ratio']:.3f}")
    print(f"  CBE S_i/S_0 = {row['cbe_ratio']:.3f} "
          f"({row['cbe_minus_be_pct']:+.1f}% vs BE)")
