"""Batched serving example: prefill + autoregressive decode with the
paper's Eq. 3 vocabulary recovery at every step.

Any assigned architecture works (--arch mamba2-1.3b serves the SSM with
O(1) decode state; --arch jamba-v0.1-52b the hybrid; reduced smoke configs
by default so it runs on CPU).

Run:  PYTHONPATH=src python examples/serve_bloom_lm.py --arch qwen3-4b
"""
import argparse

from repro import configs
from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b",
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    run(args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen)


if __name__ == "__main__":
    main()
