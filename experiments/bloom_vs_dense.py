import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Paper headline at LM scale: Bloom IO vs dense IO for qwen3-4b train_4k
on the optimized mesh — compression of the vocab boundary vs step cost."""
from repro.launch.dryrun import run_cell

for bloom in (True, False):
    res = run_cell("qwen3-4b", "train_4k", bloom=bloom,
                   overrides={"causal_skip": True}, mesh_shape=(32, 8),
                   tag="cmp", out_dir="experiments/perf",
                   optimizer="adafactor")
    r = res["roofline"]
    m = res["full"]["memory"]
    print(f"bloom={bloom} params={res['param_count'] / 1e9:.2f}B "
          f"bound={r['step_time_s']:.4f}s compute={r['compute_s']:.4f} "
          f"memory={r['memory_s']:.4f} coll={r['collective_s']:.4f} "
          f"args={m['argument_bytes'] / 2**30:.2f}GiB "
          f"temp={m['temp_bytes'] / 2**30:.2f}GiB", flush=True)
