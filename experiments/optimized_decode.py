import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Decode cells re-run at TP=16 (mesh 16x16): decode is weight-bandwidth
bound, so it wants the THINNEST weight shards (max TP) — the opposite of
training (§Perf finding: per-shape mesh selection).  Code-level wins
(one-shot bf16 weight cast halves decode weight reads) still apply."""
import time
import traceback

from repro import configs
from repro.launch.dryrun import run_cell

for arch, shape, ok, _ in configs.all_cells():
    if not ok or "decode" not in shape and shape != "long_500k":
        continue
    t0 = time.perf_counter()
    try:
        res = run_cell(arch, shape, mesh_shape=(16, 16), tag="opt",
                       out_dir="experiments/dryrun_opt_decode")
        r = res.get("roofline", {})
        print(f"OK  {arch:18s} {shape:12s} "
              f"bound={r.get('step_time_s', 0):.4f}s "
              f"[{time.perf_counter()-t0:.0f}s]", flush=True)
    except Exception as e:  # noqa
        print(f"FAIL {arch} {shape}: {e}", flush=True)
        traceback.print_exc()
print("done")
