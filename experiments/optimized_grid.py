import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Run the full 32-cell grid with the best-known (beyond-paper) settings
discovered in the §Perf hillclimb:

  * mesh (32, 8): TP=8 (kv-exact for GQA-8 archs, halves head replication
    for whisper), DP=32 (halves per-device activation traffic vs TP=16);
  * causal_skip for every causal-attention train/prefill cell;
  * adafactor (factored 2nd moment + bf16 momentum) for train cells;
  * bf16 gradient all-reduces (via the one-shot param cast);
  * flash custom-VJP attention + fused single-pass Bloom CE (code-level,
    also in the baseline rerun).

Artifacts land in experiments/dryrun_opt/ with tag 'opt'.
"""
import time
import traceback

from repro import configs
from repro.launch.dryrun import run_cell

failures = 0
for arch, shape, ok, _ in configs.all_cells():
    if not ok:
        continue
    overrides = {}
    cfg = configs.get_config(arch)
    if cfg.family not in ("ssm",) and shape in ("train_4k", "prefill_32k"):
        overrides["causal_skip"] = True
    if cfg.family == "audio":
        # whisper encoder attention is non-causal; decoder is causal —
        # causal_skip only applies to causal self-attention internally.
        pass
    t0 = time.perf_counter()
    try:
        res = run_cell(arch, shape, overrides=overrides, mesh_shape=(32, 8),
                       tag="opt", out_dir="experiments/dryrun_opt",
                       optimizer="adafactor")
        r = res.get("roofline", {})
        print(f"OK  {arch:18s} {shape:12s} "
              f"bound={r.get('step_time_s', 0):.4f}s "
              f"dom={r.get('dominant','-')} "
              f"frac={r.get('roofline_fraction', 0):.4f} "
              f"[{time.perf_counter()-t0:.0f}s]", flush=True)
    except Exception as e:  # noqa
        failures += 1
        print(f"FAIL {arch} {shape}: {e}", flush=True)
        traceback.print_exc()
print(f"done, failures={failures}")
