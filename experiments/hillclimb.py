import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb driver: run one dry-run variant of a cell and print the
roofline deltas vs the stored baseline artifact.

Usage:
  PYTHONPATH=src python experiments/hillclimb.py \
      --arch qwen3-4b --shape train_4k --tag it2_dots \
      --override remat=dots [--mesh 32x8] [--override causal_skip=true]
"""
import argparse
import json
import sys

from repro.launch.dryrun import run_cell


def parse_override(s):
    k, v = s.split("=", 1)
    if v.lower() in ("true", "false"):
        v = v.lower() == "true"
    else:
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--mesh", default=None, help="e.g. 32x8")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO-1: shard optimizer moments over data")
    ap.add_argument("--baseline", default=None,
                    help="baseline artifact (default: the dryrun one)")
    args = ap.parse_args()

    overrides = dict(parse_override(s) for s in args.override)
    mesh_shape = (tuple(int(x) for x in args.mesh.split("x"))
                  if args.mesh else None)

    res = run_cell(args.arch, args.shape, overrides=overrides,
                   mesh_shape=mesh_shape, out_dir=args.out, tag=args.tag,
                   zero=args.zero, optimizer=args.opt)

    base_path = args.baseline or (
        f"experiments/dryrun/{args.arch}__{args.shape}__singlepod.json")
    with open(base_path) as f:
        base = json.load(f)

    br, nr = base["roofline"], res["roofline"]
    bt = base["full"]["memory"]["temp_bytes"] / 2**30
    nt = res["full"]["memory"]["temp_bytes"] / 2**30

    def d(n, b):
        return f"{n:9.4f} ({(n-b)/b*100:+6.1f}%)" if b else f"{n:9.4f}"

    print(f"\n=== {args.arch} {args.shape} [{args.tag}] "
          f"overrides={overrides} mesh={mesh_shape or 'default'} ===")
    for key in ("compute_s", "memory_s", "collective_s"):
        print(f"  {key:13s} {d(nr[key], br[key])}   (base {br[key]:.4f})")
    print(f"  {'temp_GiB':13s} {d(nt, bt)}   (base {bt:.2f})")
    print(f"  dominant: {nr['dominant']}  step bound "
          f"{nr['step_time_s']:.4f} (base {br['step_time_s']:.4f}, "
          f"{(nr['step_time_s']-br['step_time_s'])/br['step_time_s']*100:+.1f}%)")
    print(f"  MODEL/HLO flops: {nr['model_flops_ratio']:.3f} "
          f"(base {br['model_flops_ratio']:.3f})")
    print(f"  roofline fraction: {nr['roofline_fraction']:.4f} "
          f"(base {br['roofline_fraction']:.4f})")


if __name__ == "__main__":
    main()
